"""Static HTML run report from one run's telemetry JSONL.

Renders a SELF-CONTAINED page (inline SVG + CSS, zero dependencies — no
matplotlib, no JS libraries; the file opens from disk or a CI artifact
tab) with:

  * the run manifest (algo, scenario config, commit, mesh, wire cost),
  * the convergence curve (log-y) of the residual series with the fitted
    linear rate rho_hat annotated and every monitor WARN (invariant
    violations, rate breaks) marked at its round,
  * distribution ribbons for each sketch source present (p50/p90/p99/max
    bands of per-client ||d_i||, drift, compression error, staleness age
    — the population view that mean curves hide),
  * the communication budget (cumulative uplink/downlink bits from the
    bit-true per-round accounting),
  * the budget-vs-leaf breakdown (exact per-leaf wire bits from the
    manifest — compression-plan rules and actual kept counts included —
    joined with each leaf's mean compress_err from the ``leaf_stats``
    events), and
  * the perf trajectory table from ``results/BENCH_trajectory.json``
    when present (one row per bench timing).

Usage:
    python benchmarks/report.py run.jsonl -o report.html \
        [--trajectory results/BENCH_trajectory.json]

The rate fit here is the same windowed log-residual regression the drain
runs live (core/telemetry.py:fit_rate) — reimplemented in stdlib math so
the report renders anywhere the JSONL lands.
"""

from __future__ import annotations

import argparse
import html
import json
import math
import os

W, H = 820, 300
PAD_L, PAD_R, PAD_T, PAD_B = 64, 16, 28, 40
COLORS = ["#1f77b4", "#d62728", "#2ca02c", "#9467bd", "#ff7f0e", "#8c564b"]
RIBBON_SOURCES = ("d_norm", "drift", "compress_err", "age")


# ------------------------------------------------------------------ data
def load_events(path: str):
    manifest, rounds, warns, leaves = None, [], [], []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            ev = json.loads(line)
            kind = ev.get("event")
            if kind == "manifest" and manifest is None:
                manifest = ev
            elif kind == "round":
                rounds.append(ev)
            elif kind == "monitor" and ev.get("level") == "WARN":
                warns.append(ev)
            elif kind == "leaf_stats":
                leaves.append(ev)
    return manifest, rounds, warns, leaves


def fit_rate(rounds, values) -> float | None:
    """exp(least-squares slope of ln(v) vs round) — core/telemetry.py:
    fit_rate in stdlib math (the report must render without the repo)."""
    pts = [(r, math.log(v)) for r, v in zip(rounds, values) if v > 0]
    if len(pts) < 3:
        return None
    n = len(pts)
    mr = sum(p[0] for p in pts) / n
    mv = sum(p[1] for p in pts) / n
    den = sum((p[0] - mr) ** 2 for p in pts)
    if den == 0:
        return None
    return math.exp(sum((p[0] - mr) * (p[1] - mv) for p in pts) / den)


def residual_series(rounds):
    """The convergence series: distance-to-optimum when the run logged it
    (quadratic sims), else the loss curve (LM runs)."""
    for key in ("err", "loss", "invariant_residual"):
        xs = [e["round"] for e in rounds if isinstance(e.get(key), (int, float))]
        ys = [e[key] for e in rounds if isinstance(e.get(key), (int, float))]
        if len(ys) >= 2:
            return key, xs, ys
    return None, [], []


# ------------------------------------------------------------------- svg
class Chart:
    """Linear/log-y data-to-pixel mapping + primitive emitters."""

    def __init__(self, xs, ys_all, *, logy: bool):
        self.logy = logy
        self.x0, self.x1 = min(xs), max(xs)
        vals = [v for v in ys_all if not logy or v > 0]
        if not vals:
            vals = [1e-12, 1.0]
        lo, hi = min(vals), max(vals)
        if logy:
            self.y0, self.y1 = math.log10(lo), math.log10(max(hi, lo * 10))
        else:
            span = (hi - lo) or 1.0
            self.y0, self.y1 = lo - 0.05 * span, hi + 0.05 * span
        if self.x1 == self.x0:
            self.x1 = self.x0 + 1
        if self.y1 == self.y0:
            self.y1 += 1

    def px(self, x):
        return PAD_L + (x - self.x0) / (self.x1 - self.x0) * (W - PAD_L - PAD_R)

    def py(self, y):
        v = math.log10(y) if self.logy else y
        frac = (v - self.y0) / (self.y1 - self.y0)
        return H - PAD_B - frac * (H - PAD_T - PAD_B)

    def polyline(self, xs, ys, color, width=1.6, dash=""):
        pts = " ".join(f"{self.px(x):.1f},{self.py(y):.1f}"
                       for x, y in zip(xs, ys)
                       if not self.logy or y > 0)
        d = f' stroke-dasharray="{dash}"' if dash else ""
        return (f'<polyline points="{pts}" fill="none" stroke="{color}" '
                f'stroke-width="{width}"{d}/>')

    def band(self, xs, lo_ys, hi_ys, color, opacity=0.18):
        fwd = [(x, y) for x, y in zip(xs, hi_ys) if not self.logy or y > 0]
        bwd = [(x, y) for x, y in zip(xs, lo_ys) if not self.logy or y > 0]
        if not fwd or not bwd:
            return ""
        pts = " ".join(f"{self.px(x):.1f},{self.py(y):.1f}" for x, y in fwd)
        pts += " " + " ".join(f"{self.px(x):.1f},{self.py(y):.1f}"
                              for x, y in reversed(bwd))
        return (f'<polygon points="{pts}" fill="{color}" '
                f'opacity="{opacity}" stroke="none"/>')

    def vmark(self, x, color="#d62728"):
        return (f'<line x1="{self.px(x):.1f}" y1="{PAD_T}" '
                f'x2="{self.px(x):.1f}" y2="{H - PAD_B}" stroke="{color}" '
                f'stroke-width="1" stroke-dasharray="3,3" opacity="0.7"/>')

    def axes(self, n_yticks=5, n_xticks=6):
        out = [f'<rect x="{PAD_L}" y="{PAD_T}" width="{W - PAD_L - PAD_R}" '
               f'height="{H - PAD_T - PAD_B}" fill="none" stroke="#ccc"/>']
        for i in range(n_yticks + 1):
            v = self.y0 + (self.y1 - self.y0) * i / n_yticks
            y = H - PAD_B - (H - PAD_T - PAD_B) * i / n_yticks
            lbl = f"1e{v:.0f}" if self.logy else f"{v:.3g}"
            out.append(f'<line x1="{PAD_L - 4}" y1="{y:.1f}" x2="{PAD_L}" '
                       f'y2="{y:.1f}" stroke="#888"/>')
            out.append(f'<text x="{PAD_L - 8}" y="{y + 4:.1f}" '
                       f'text-anchor="end" class="tick">{lbl}</text>')
        for i in range(n_xticks + 1):
            x = self.x0 + (self.x1 - self.x0) * i / n_xticks
            px = PAD_L + (W - PAD_L - PAD_R) * i / n_xticks
            out.append(f'<line x1="{px:.1f}" y1="{H - PAD_B}" x2="{px:.1f}" '
                       f'y2="{H - PAD_B + 4}" stroke="#888"/>')
            out.append(f'<text x="{px:.1f}" y="{H - PAD_B + 16}" '
                       f'text-anchor="middle" class="tick">{x:.0f}</text>')
        return "".join(out)


def svg(title: str, body: str, legend: list[tuple[str, str]] = ()) -> str:
    leg = ""
    lx = PAD_L + 8
    for name, color in legend:
        leg += (f'<rect x="{lx}" y="{PAD_T + 6}" width="12" height="3" '
                f'fill="{color}"/>'
                f'<text x="{lx + 16}" y="{PAD_T + 11}" class="tick">'
                f'{html.escape(name)}</text>')
        lx += 16 + 7 * len(name) + 18
    return (f'<svg viewBox="0 0 {W} {H}" class="chart" role="img">'
            f'<text x="{PAD_L}" y="16" class="title">{html.escape(title)}'
            f"</text>{body}{leg}</svg>")


# -------------------------------------------------------------- sections
def convergence_section(rounds, warns) -> str:
    key, xs, ys = residual_series(rounds)
    if key is None:
        return "<p>No residual series in this run's round events.</p>"
    rho = fit_rate(xs, ys)
    # prefer the live-annotated estimate when the drain ran a RateMonitor
    rho_live = [e["rho_hat"] for e in rounds
                if isinstance(e.get("rho_hat"), (int, float))]
    ch = Chart(xs, ys, logy=all(v > 0 for v in ys))
    body = ch.axes() + ch.polyline(xs, ys, COLORS[0])
    marks, legend = "", [(key, COLORS[0])]
    for w in warns:
        if w.get("round") is not None:
            marks += ch.vmark(w["round"])
    rate_breaks = [w for w in warns if w.get("kind") == "rate_break"]
    rho_txt = f"rho_hat = {rho:.4f} (whole-run fit)" if rho else ""
    if rho_live:
        rho_txt = f"rho_hat = {rho_live[-1]:.4f} (windowed, live)"
    note = ""
    if rate_breaks:
        b = rate_breaks[0]
        note = (f'<p class="warn">RATE BREAK at round {b.get("round")}: '
                f'rho_hat {b.get("rho_hat"):.4f} after established '
                f'{b.get("rho_ref"):.4f} — suspect axis: '
                f'{html.escape(str(b.get("axis", "")))}</p>')
    extra = (f'<text x="{W - PAD_R - 6}" y="{PAD_T + 14}" text-anchor="end" '
             f'class="anno">{rho_txt}</text>') if rho_txt else ""
    return (svg(f"convergence ({key}, {len(warns)} WARNs marked)",
                body + marks + extra, legend) + note)


def ribbon_section(rounds) -> str:
    out = []
    for i, src in enumerate(RIBBON_SOURCES):
        keys = [f"{src}_p50", f"{src}_p90", f"{src}_p99", f"{src}_max"]
        sel = [e for e in rounds
               if all(isinstance(e.get(k), (int, float)) for k in keys)]
        if len(sel) < 2:
            continue
        xs = [e["round"] for e in sel]
        p50 = [e[keys[0]] for e in sel]
        p90 = [e[keys[1]] for e in sel]
        p99 = [e[keys[2]] for e in sel]
        mx = [e[keys[3]] for e in sel]
        col = COLORS[i % len(COLORS)]
        logy = all(v > 0 for v in p50 + mx)
        ch = Chart(xs, p50 + p90 + p99 + mx, logy=logy)
        body = (ch.axes() + ch.band(xs, p50, p99, col)
                + ch.polyline(xs, p50, col)
                + ch.polyline(xs, p90, col, width=1.0, dash="4,3")
                + ch.polyline(xs, mx, col, width=1.0, dash="1,3"))
        out.append(svg(f"{src} per-client distribution "
                       "(p50 solid / p90 dashed / p99 band / max dotted)",
                       body, [(src, col)]))
    if not out:
        return ("<p>No distribution sketches in this run — launch with "
                "<code>--telemetry ...,hist:48,topk:4</code>.</p>")
    return "".join(out)


def comm_section(manifest, rounds) -> str:
    xs, up, dn = [], [], []
    cu = cd = 0.0
    for e in rounds:
        bu, bd = e.get("bits_up"), e.get("bits_down")
        if not isinstance(bu, (int, float)):
            continue
        cu += bu
        cd += bd if isinstance(bd, (int, float)) else 0.0
        xs.append(e["round"])
        up.append(cu)
        dn.append(cd)
    if len(xs) < 2:
        bits = (manifest or {}).get("bits_per_round")
        return (f"<p>Per-round wire cost: <code>{html.escape(json.dumps(bits))}"
                "</code></p>" if bits else "<p>No comm accounting logged.</p>")
    ch = Chart(xs, up + dn, logy=False)
    body = (ch.axes() + ch.polyline(xs, up, COLORS[0])
            + ch.polyline(xs, dn, COLORS[4], dash="4,3"))
    tot = (f'<p>Total uplink {up[-1]:.3e} bits, downlink {dn[-1]:.3e} bits '
           f'over {len(xs)} rounds.</p>')
    return svg("cumulative communication budget (bits)", body,
               [("uplink", COLORS[0]), ("downlink", COLORS[4])]) + tot


def leaf_budget_section(manifest, leaves) -> str:
    """Budget-vs-leaf breakdown: how the per-round uplink bits split
    across message leaves (the manifest's exact per-leaf billing — plan
    rules and actual kept counts included), joined against the mean
    per-leaf compression error from the run's ``leaf_stats`` events."""
    man = manifest or {}
    names = man.get("leaf_names")
    bits = man.get("leaf_bits")
    sizes = man.get("leaf_sizes")
    err_sum, err_n = {}, {}
    for ev in leaves:
        if names is None and isinstance(ev.get("names"), list):
            names = ev["names"]
        if bits is None and isinstance(ev.get("bits"), list):
            bits = ev["bits"]
        errs = ev.get("compress_err")
        if isinstance(errs, list):
            for i, v in enumerate(errs):
                if isinstance(v, (int, float)):
                    err_sum[i] = err_sum.get(i, 0.0) + v
                    err_n[i] = err_n.get(i, 0) + 1
    if not names or not isinstance(bits, list):
        return ("<p>No per-leaf billing in this run — needs an algorithm "
                "whose compressor stack decomposes per leaf (manifest "
                "<code>leaf_bits</code>).</p>")
    total = sum(bits) or 1.0
    rows = []
    for i, nm in enumerate(names):
        b = bits[i] if i < len(bits) else None
        if not isinstance(b, (int, float)):
            continue
        n = sizes[i] if sizes and i < len(sizes) else None
        per = f"{b / n:.2f}" if n else "—"
        err = (f"{err_sum[i] / err_n[i]:.3e}"
               if err_n.get(i) else "—")
        rows.append(f"<tr><td><code>{html.escape(str(nm))}</code></td>"
                    f"<td>{n if n else '—'}</td><td>{per}</td>"
                    f"<td>{b:.0f}</td>"
                    f"<td>{100.0 * b / total:.1f}%</td>"
                    f"<td>{err}</td></tr>")
    if not rows:
        return "<p>No per-leaf billing in this run.</p>"
    return ("<table><tr><th>leaf</th><th>coords</th><th>bits/coord</th>"
            "<th>bits/round</th><th>budget share</th>"
            "<th>mean compress_err</th></tr>" + "".join(rows)
            + f"</table><p>Total client-hop uplink: {total:.3e} bits "
              "per client per round (exact per-leaf accounting).</p>")


def trajectory_section(path: str | None) -> str:
    if not path or not os.path.exists(path):
        return ""
    try:
        traj = json.loads(open(path).read())
    except (OSError, json.JSONDecodeError):
        return ""
    benches = traj.get("benchmarks", traj if isinstance(traj, dict) else {})
    rows = []
    for name in sorted(benches):
        b = benches[name]
        if not isinstance(b, dict):
            continue
        for k, v in sorted(b.get("timings_us", {}).items()):
            if isinstance(v, (int, float)):
                rows.append(f"<tr><td>{html.escape(str(name))}</td>"
                            f"<td>{html.escape(k)}</td>"
                            f"<td>{v:.1f}</td></tr>")
    if not rows:
        return ""
    return ("<h2>Perf trajectory</h2><table><tr><th>bench</th><th>timing"
            "</th><th>us</th></tr>" + "".join(rows) + "</table>")


def manifest_section(manifest) -> str:
    if not manifest:
        return "<p>No manifest event found.</p>"
    cfg = manifest.get("config", {})
    rows = [("algo", manifest.get("algo")),
            ("n_clients", manifest.get("n_clients")),
            ("tau", manifest.get("tau")),
            ("commit", manifest.get("commit")),
            ("mesh", json.dumps(manifest.get("mesh")))]
    rows += sorted(cfg.items())
    cells = "".join(f"<tr><td>{html.escape(str(k))}</td>"
                    f"<td><code>{html.escape(str(v))}</code></td></tr>"
                    for k, v in rows)
    return f"<table>{cells}</table>"


STYLE = """
body { font-family: -apple-system, 'Segoe UI', Roboto, sans-serif;
       max-width: 900px; margin: 24px auto; color: #222; }
h1 { font-size: 1.4em; } h2 { font-size: 1.1em; margin-top: 28px; }
table { border-collapse: collapse; font-size: 0.85em; }
td, th { border: 1px solid #ddd; padding: 3px 8px; text-align: left; }
svg.chart { width: 100%; height: auto; margin: 8px 0; }
svg .title { font-size: 13px; font-weight: 600; }
svg .tick { font-size: 10px; fill: #555; }
svg .anno { font-size: 12px; fill: #d62728; font-weight: 600; }
p.warn { color: #b71c1c; font-weight: 600; }
code { background: #f5f5f5; padding: 1px 4px; }
"""


def render(jsonl_path: str, trajectory: str | None = None) -> str:
    manifest, rounds, warns, leaves = load_events(jsonl_path)
    parts = [
        "<!doctype html><html><head><meta charset='utf-8'>",
        f"<title>run report — {html.escape(os.path.basename(jsonl_path))}"
        f"</title><style>{STYLE}</style></head><body>",
        f"<h1>Run report — <code>{html.escape(jsonl_path)}</code></h1>",
        "<h2>Manifest</h2>", manifest_section(manifest),
        "<h2>Convergence &amp; linear rate</h2>",
        convergence_section(rounds, warns),
        "<h2>Population distribution ribbons</h2>", ribbon_section(rounds),
        "<h2>Communication budget</h2>", comm_section(manifest, rounds),
        "<h2>Budget vs leaf</h2>", leaf_budget_section(manifest, leaves),
        trajectory_section(trajectory),
        "</body></html>",
    ]
    return "".join(parts)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("jsonl", help="telemetry JSONL from a run "
                                  "(--telemetry jsonl:<path>,...)")
    ap.add_argument("-o", "--out", default="report.html")
    ap.add_argument("--trajectory", default=None,
                    help="results/BENCH_trajectory.json for the perf table")
    args = ap.parse_args(argv)
    doc = render(args.jsonl, args.trajectory)
    with open(args.out, "w") as f:
        f.write(doc)
    print(f"wrote {args.out} ({len(doc)} bytes)")


if __name__ == "__main__":
    main()
