"""Benchmark: O(cohort) round execution vs the dense O(N) vmap path.

The cohort engine (``with_cohort``, repro/core/engine.py) keeps all
per-client state in a server-side ``[N, ...]`` client-state store; each
round it gathers the sampled cohort's rows into a fixed-shape ``[m, ...]``
batch, runs the vmap-lifted local scan on the cohort only, and scatters
the updated rows back — in place, because the round runner donates the
carry. Per-round compute is then O(m·D) regardless of the population
size N, while the dense path vmaps the local scan over all N rows.

This script sweeps N = 1e3 -> 1e6 at a FIXED cohort size (256, the
``block`` selector — O(m) index arithmetic, no O(N) permutation) on the
paper's quadratic problem and asserts the PINNED SCALING FINDINGS
(committed in results/cohort_scaling.csv + results/BENCH_cohort_scaling
.json; recorded in ARCHITECTURE.md):

1. cohort round time is ~flat in N: stepping N=1e4 -> 1e6 (100x rows)
   grows the measured round time by <= 1.5x;
2. the dense path is ~linear: N=1e4 -> 1e5 (10x) grows it by >= 3x;
3. exactness survives the rewrite: at N=1e3 the gather lowering matches
   the dense reference lowering <= 1e-12 after 4 rounds for all four
   algorithm families (FedCET, FedAvg, SCAFFOLD, FedTrack).

Run directly (``python benchmarks/cohort_scaling.py``) or via
benchmarks/run.py; ``--quick`` caps the sweep at N=1e4 for CI smoke
(the scaling assertions need the full sweep and are skipped).
"""

from __future__ import annotations

try:
    from benchmarks._timing import min_of_batches, results_dir, \
        write_bench_json
except ImportError:  # run directly as a script: benchmarks/ is sys.path[0]
    from _timing import min_of_batches, results_dir, write_bench_json

COHORT = 256
DIM = 8
TAU = 2
ROUNDS = 4       # rounds per timed call (scan length); time is per round
REPS = 2
BATCHES = 3
NS_GATHER = (1_000, 10_000, 100_000, 1_000_000)
NS_DENSE = (1_000, 10_000, 100_000)  # the O(N) reference stops at 1e5
EQUIV_N = 1_000
EQUIV_TOL = 1e-12


def _problem(n: int):
    from repro.data.quadratic import make_quadratic_problem

    return make_quadratic_problem(0, n_clients=n, n_measurements=1, dim=DIM)


def _algos(n: int) -> dict:
    from repro.core import FedAvg, FedCET, FedTrack, Scaffold

    return {
        "fedcet": FedCET(alpha=0.02, c=0.3, tau=TAU, n_clients=n),
        "fedavg": FedAvg(alpha=0.05, tau=TAU, n_clients=n),
        "scaffold": Scaffold(alpha_l=0.02, tau=TAU, n_clients=n),
        "fedtrack": FedTrack(alpha=0.02, tau=TAU, n_clients=n),
    }


def _init_state(algo, prob):
    import jax
    import jax.numpy as jnp

    grad = jax.grad(prob.client_loss)
    batches = prob.stacked_batches(TAU)
    first = jax.tree.map(lambda b: b[0], batches)
    state = algo.init(grad, jnp.zeros((prob.dim,), prob.b.dtype), first)
    return grad, state, batches


def _time_rounds(algo, prob) -> float:
    """Best-of-batches per-ROUND microseconds for `algo` on `prob`, timing
    the donated repeat-mode runner (in-place client-store updates)."""
    from repro.core import make_round_runner

    grad, state, batches = _init_state(algo, prob)
    runner = make_round_runner(algo, grad, repeat=True, donate=True)
    holder = {"s": state}  # donated carry: rebind every call

    def once():
        s, _ = runner(holder["s"], batches, ROUNDS)
        holder["s"] = s
        return s

    best_us, _ = min_of_batches(once, reps=REPS, batches=BATCHES)
    return best_us / ROUNDS


def _equiv_gap(algo_g, algo_d, prob) -> float:
    """Max-abs final-state gap between the two cohort lowerings."""
    import jax

    from repro.core import run_rounds

    gaps = []
    for a in (algo_g, algo_d):
        grad, state, batches = _init_state(a, prob)
        final, _ = run_rounds(a, grad, state, batches, rounds=ROUNDS)
        gaps.append(final)
    return max(float(abs(lg - ld).max())
               for lg, ld in zip(jax.tree.leaves(gaps[0]),
                                 jax.tree.leaves(gaps[1])))


def run(csv_rows=None, quick: bool = False):
    import jax

    jax.config.update("jax_enable_x64", True)  # the <=1e-12 exactness pin

    from repro.core import CohortSpec, with_cohort

    ns_gather = tuple(n for n in NS_GATHER if n <= 10_000) if quick \
        else NS_GATHER
    ns_dense = tuple(n for n in NS_DENSE if n <= 10_000) if quick \
        else NS_DENSE
    spec = lambda lowering: CohortSpec(size=COHORT, selector="block",  # noqa: E731
                                       lowering=lowering)
    times = {}

    for n in ns_gather:
        prob = _problem(n)
        algo = with_cohort(_algos(n)["fedcet"], spec("gather"))
        t = _time_rounds(algo, prob)
        times[("gather", n)] = t
        if csv_rows is not None:
            csv_rows.append((f"cohort_scaling/gather/n{n}", t,
                             f"cohort={COHORT};dim={DIM};tau={TAU}"))
    for n in ns_dense:
        prob = _problem(n)
        t = _time_rounds(_algos(n)["fedcet"], prob)  # bare: dense O(N) path
        times[("dense", n)] = t
        if csv_rows is not None:
            csv_rows.append((f"cohort_scaling/dense/n{n}", t,
                             f"cohort=none;dim={DIM};tau={TAU}"))

    # ---- exactness: gather lowering == dense reference lowering, all four
    # algorithm families, on the same cohort schedule.
    prob = _problem(EQUIV_N)
    equiv = {}
    for name, algo in _algos(EQUIV_N).items():
        gap = _equiv_gap(with_cohort(algo, spec("gather")),
                         with_cohort(algo, spec("dense")), prob)
        equiv[name] = gap
        assert gap <= EQUIV_TOL, (name, gap)
        if csv_rows is not None:
            csv_rows.append((f"cohort_scaling/equiv/{name}", 0.0,
                             f"max_abs_gap={gap:.3e};n={EQUIV_N}"))

    write_bench_json(
        "cohort_scaling",
        config={"cohort": COHORT, "selector": "block", "dim": DIM,
                "tau": TAU, "rounds_per_call": ROUNDS, "reps": REPS,
                "batches": BATCHES, "ns_gather": list(ns_gather),
                "ns_dense": list(ns_dense), "quick": quick},
        timings={f"{path}/n{n}": t for (path, n), t in times.items()},
        extra={"equiv_max_abs_gap": {k: float(v) for k, v in equiv.items()},
               "equiv_n": EQUIV_N, "equiv_rounds": ROUNDS},
        out_dir=results_dir())

    # ---- pinned measured findings (full sweep only; see module docstring)
    if not quick:
        n_top = ns_gather[-1]
        grow_c = times[("gather", n_top)] / times[("gather", 10_000)]
        assert grow_c <= 1.5, (
            "cohort round time must stay ~flat in N", n_top, grow_c)
        grow_d = times[("dense", 100_000)] / times[("dense", 10_000)]
        assert grow_d >= 3.0, (
            "dense round time must grow ~linearly in N", grow_d)
    return times


if __name__ == "__main__":
    import sys

    rows = []
    run(csv_rows=rows, quick="--quick" in sys.argv)
    print("name,us_per_call,derived")
    for r in rows:
        print(",".join(map(str, r)))
