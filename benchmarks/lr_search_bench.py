"""Benchmark: Algorithm 1 (learning-rate search) — output alpha and wall
time across conditioning regimes, plus the granularity trade-off of Remark 1
(finer h => larger feasible alpha, more search steps)."""

from __future__ import annotations

import time

from repro.core.lr_search import contraction_factors, lr_search


def run(csv_rows=None):
    cases = [(4.0, 4.0, 2), (4.0, 4.0, 8), (1.0, 10.0, 2), (0.5, 5.0, 4)]
    for mu, L, tau in cases:
        for h_frac in (1e-2, 1e-3, 1e-4):
            t0 = time.perf_counter()
            alpha = lr_search(mu, L, tau, h_frac=h_frac)
            us = (time.perf_counter() - t0) * 1e6
            cf = contraction_factors(alpha, mu, L, tau, n_clients=10)
            if csv_rows is not None:
                csv_rows.append((
                    f"lr_search/mu{mu}_L{L}_tau{tau}_h{h_frac:g}", us,
                    f"alpha={alpha:.6e};rho={cf.rho:.6f}"))
            assert cf.converges


if __name__ == "__main__":
    rows = []
    run(csv_rows=rows)
    for r in rows:
        print(",".join(map(str, r)))
