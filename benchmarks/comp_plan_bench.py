"""Benchmark: per-leaf compression plans vs the uniform uplink on the LM
track — does a bit-budget allocator buy a better error floor at the SAME
measured wire cost?

Uniform ``shift:q8`` spends 8 bits on every coordinate of every leaf.
The allocator (``CompressionPlan.allocate``) instead water-fills the same
TOTAL budget across leaves by sensitivity: dithered quantization at ``b``
bits on a leaf with RMS ``s`` costs ``~ n * s^2 * 4^-b`` mean-square
error, so the marginal value of one more bit on leaf ``i`` is
``s_i^2 * 4^-b_i`` and the optimum equalizes it across leaves. We use
``sensitivity='absmax'`` — StochasticQuant scales its grid to
``max|leaf|``, so absmax is the model-matched weighting. On the
fedlm-100m geometry the norm scales are zeros-init (residual
parametrization, zero quantization error at any width) and get dropped
to the floor, freeing bits that flow into the widest-range matmuls
(mlp/up) at the expense of the flatter embedding tables.

Two measurements, both at a budget pinned to the MEASURED uniform
shift:q8 bits/round (exact per-leaf accounting, actual kept counts):

1. **quantization error head-to-head** — one round-message-shaped tree
   (the model parameters: FedCET transmits the ABSOLUTE iterate, so
   params are the right scale model) through both compressor stacks;
   relative MSE must drop under the plan at <= the uniform bits;
2. **LM training** — ``launch.train.run_training`` end to end, uniform
   vs allocated plan at the same round count and data; the plan must
   land at-or-below the uniform loss while its meter (bit-true,
   per-leaf) reports equal-or-fewer transmitted bytes.

Committed findings live in results/BENCH_comp_plan.json; full (non
``--quick``) runs re-assert:

* plan bits/round <= uniform bits/round (measured, per-leaf exact);
* plan quantization MSE <= MSE_WIN_MAX x uniform MSE (the allocator's
  whole point — measured ~0.85x on this init-time geometry, where the
  sensitivity spread across matmul leaves is modest);
* plan final LM loss <= LOSS_WASH_MAX x uniform final loss (the error
  win must not cost convergence).

``--quick`` (CI) shrinks rounds/clients and skips the assertions.
"""

from __future__ import annotations

try:
    from benchmarks._timing import results_dir, write_bench_json
except ImportError:  # run directly as a script: benchmarks/ is sys.path[0]
    from _timing import results_dir, write_bench_json

ARCH = "fedlm-100m"
CLIENTS = 8
TAU = 2
BATCH = 2
SEQ = 32
ROUNDS = 24          # quick: 4
SEED = 0

# conservative pins under the measured findings (full mode only).
MSE_WIN_MAX = 0.95   # plan quant MSE <= 0.95x uniform's (measured ~0.85)
LOSS_WASH_MAX = 1.02  # plan final loss within 2% of uniform (or better)


def _budget_and_plan(quick: bool):
    """The measured uniform shift:q8 bits/round and the sensitivity-
    weighted plan allocated to exactly that budget (both exact per-leaf
    accounting — actual kept counts, first-narrowest-wins chains)."""
    import jax

    from repro.configs import get_config
    from repro.core import (CompressionPlan, FedCET, leaf_info_of,
                            message_leaf_bits_of, with_compression)
    from repro.models import build_model

    cfg = get_config(ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(SEED))
    info = leaf_info_of(params)

    n = 4 if quick else CLIENTS
    uniform = with_compression(
        FedCET(alpha=3e-3, c=0.05, tau=TAU, n_clients=n),
        compressor="shift:q8", seed=SEED)
    uniform_leaf_bits = message_leaf_bits_of(uniform, info)
    budget = float(sum(uniform_leaf_bits))

    plan = CompressionPlan().allocate(
        budget, leaves=params, sensitivity="absmax", wrap="shift",
        min_bits=2, max_bits=14)
    plan_bits = float(sum(plan.tree_wire_bits(info)))
    return model, params, info, budget, plan, plan_bits


def quant_error_head_to_head(plan, params, csv_rows=None) -> dict:
    """Relative quantization MSE of one message-shaped tree through the
    uniform q8 stack vs the plan's per-leaf stacks (bare quantizers — the
    shift wrappers share the same inner quantizer on round one, when the
    shift memory is zero, so this IS the round-one compression error)."""
    import jax
    import jax.numpy as jnp

    from repro.core.compressors import ErrorFeedback, Shifted, from_spec

    def strip(c):
        return c.inner if isinstance(c, (ErrorFeedback, Shifted)) else c

    key = jax.random.key(7)
    flat, _ = jax.tree_util.tree_flatten(params)

    def tree_mse(comp_for_leaf):
        num = den = 0.0
        for i, leaf in enumerate(flat):
            comp = comp_for_leaf(i)
            sub = jax.random.fold_in(key, i)
            q = leaf if comp is None else comp.compress(
                sub if comp.requires_key else None, leaf[None])[0]
            num += float(jnp.sum(jnp.square(q - leaf)))
            den += float(jnp.sum(jnp.square(leaf)))
        return num / den

    from repro.core.comm import leaf_info_of

    names = [nm for nm, _ in leaf_info_of(params)]
    q8 = strip(from_spec("shift:q8"))
    mse_uniform = tree_mse(lambda i: q8)
    mse_plan = tree_mse(lambda i: strip(plan.resolve(i, names[i])))
    out = {"mse_uniform_q8": mse_uniform, "mse_plan": mse_plan,
           "mse_ratio": mse_plan / mse_uniform}
    if csv_rows is not None:
        csv_rows.append(("comp_plan/quant_mse_ratio", out["mse_ratio"],
                         f"uniform={mse_uniform:.3e};plan={mse_plan:.3e}"))
    return out


def lm_track(plan, quick: bool, csv_rows=None) -> dict:
    """End-to-end LM training, uniform shift:q8 vs the allocated plan —
    same data, seed and round count; per-leaf bit-true comm metering."""
    import time

    from repro.launch.train import run_training

    n = 4 if quick else CLIENTS
    rounds = 4 if quick else ROUNDS
    out = {}
    for name, kw in (("uniform_q8", {"compression": "shift:q8"}),
                     ("plan", {"compression_plan": plan})):
        t0 = time.perf_counter()
        hist = run_training(ARCH, steps=rounds, tau=TAU, n_clients=n,
                            batch=BATCH, seq_len=SEQ, seed=SEED,
                            log_every=max(rounds // 2, 1), **kw)
        wall = time.perf_counter() - t0
        out[name] = {"loss": hist["loss"][-1],
                     "comm_bytes": hist["comm_bytes"][-1],
                     "round_us": wall / rounds * 1e6}
        if csv_rows is not None:
            csv_rows.append((f"comp_plan/loss/{name}", hist["loss"][-1],
                             f"rounds={rounds};bytes={hist['comm_bytes'][-1]}"))
    out["loss_ratio"] = out["plan"]["loss"] / out["uniform_q8"]["loss"]
    out["bytes_ratio"] = (out["plan"]["comm_bytes"]
                          / out["uniform_q8"]["comm_bytes"])
    if csv_rows is not None:
        csv_rows.append(("comp_plan/loss_ratio", out["loss_ratio"],
                         f"bytes_ratio={out['bytes_ratio']:.6f}"))
    return out


def run(csv_rows=None, quick: bool = False):
    model, params, info, budget, plan, plan_bits = _budget_and_plan(quick)
    n_total = sum(n for _, n in info)
    if csv_rows is not None:
        csv_rows.append(("comp_plan/bits_per_coord", plan_bits / n_total,
                         f"uniform={budget / n_total:.4f};"
                         f"leaves={len(info)}"))
    mse = quant_error_head_to_head(plan, params, csv_rows)
    track = lm_track(plan, quick, csv_rows)

    write_bench_json(
        "comp_plan",
        config={"arch": ARCH, "clients": (4 if quick else CLIENTS),
                "tau": TAU, "batch": BATCH, "seq": SEQ,
                "rounds": (4 if quick else ROUNDS), "seed": SEED,
                "budget_bits_per_round": budget, "quick": quick,
                "sensitivity": "absmax", "wrap": "shift"},
        timings={"round/uniform_q8": track["uniform_q8"]["round_us"],
                 "round/plan": track["plan"]["round_us"]},
        extra={"bits": {"uniform_q8": budget, "plan": plan_bits,
                        "ratio": plan_bits / budget},
               "quant_mse": mse,
               "lm_track": track,
               "plan_rules": [(pat, repr(c)) for pat, c in plan.rules]},
        out_dir=results_dir())

    # ---- pinned findings (full mode only; see module docstring)
    if not quick:
        assert plan_bits <= budget + 1e-9, (
            "allocated plan exceeds the measured uniform budget",
            plan_bits, budget)
        assert mse["mse_ratio"] <= MSE_WIN_MAX, (
            "plan no longer beats uniform q8 on quantization error at "
            "matched bits", mse)
        assert track["loss_ratio"] <= LOSS_WASH_MAX, (
            "plan loss fell off the uniform baseline", track)
        assert track["bytes_ratio"] <= 1.0 + 1e-9, (
            "plan transmitted more than uniform", track)
    return {"bits": {"uniform": budget, "plan": plan_bits}, "mse": mse,
            "track": track}


if __name__ == "__main__":
    import sys

    rows = []
    run(csv_rows=rows, quick="--quick" in sys.argv)
    print("name,us_per_call,derived")
    for r in rows:
        print(",".join(map(str, r)))
