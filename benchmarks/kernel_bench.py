"""Benchmark: Pallas FedCET-update kernels vs jnp reference (CPU interpret
mode — correctness-trend numbers, not TPU timings) across sizes."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) * 1e6 / iters


def run(csv_rows=None):
    jref_v = jax.jit(ref.fedcet_v, static_argnames=("alpha",))
    for n in (1 << 16, 1 << 20, 1 << 22):
        ks = jax.random.split(jax.random.key(0), 3)
        x, g, d = (jax.random.normal(k, (n,), dtype=jnp.float32) for k in ks)
        t_kernel = _time(lambda *a: ops.fedcet_v(*a, 0.01), x, g, d)
        t_ref = _time(lambda *a: jref_v(*a, alpha=0.01), x, g, d)
        if csv_rows is not None:
            csv_rows.append((f"kernel/fedcet_v/n{n}", t_kernel,
                             f"ref_us={t_ref:.1f};interpret=True"))


if __name__ == "__main__":
    rows = []
    run(csv_rows=rows)
    for r in rows:
        print(",".join(map(str, r)))
