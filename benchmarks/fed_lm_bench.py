"""Benchmark: federated LM round throughput on the host device — wall time
per FedCET round vs baselines on the reduced fedlm config, plus the
error-vs-bytes trade-off on the quadratic problem (the paper's
communication-efficiency claim in benchmark form)."""

from __future__ import annotations

import time

import jax

from repro.configs import get_config
from repro.core import FedAvg, FedCET, FedTrack, Scaffold
from repro.core.simulate import simulate_quadratic
from repro.data.quadratic import make_quadratic_problem
from repro.data.synthetic import make_hetero_lm_dataset
from repro.models import build_model


def lm_round_times(csv_rows=None):
    cfg = get_config("fedlm-100m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    n_clients, tau, B, S = 4, 2, 4, 64
    ds = make_hetero_lm_dataset(cfg.vocab_size, n_clients, S, B, seed=0)
    batches = {"tokens": ds.sample_round(0, tau)}
    init_b = jax.tree.map(lambda b: b[0], batches)
    grad_fn = jax.grad(model.loss)
    algos = {
        "fedcet": FedCET(alpha=3e-3, c=0.05, tau=tau, n_clients=n_clients),
        "fedavg": FedAvg(alpha=3e-3, tau=tau, n_clients=n_clients),
        "scaffold": Scaffold(alpha_l=3e-3, tau=tau, n_clients=n_clients),
        "fedtrack": FedTrack(alpha=3e-3, tau=tau, n_clients=n_clients),
    }
    for name, algo in algos.items():
        state = algo.init(grad_fn, params, init_b)
        step = jax.jit(lambda s, b, a=algo: a.round(grad_fn, s, b))
        state = step(state, batches)  # compile
        jax.block_until_ready(state)
        t0 = time.perf_counter()
        for _ in range(3):
            state = step(state, batches)
        jax.block_until_ready(state)
        us = (time.perf_counter() - t0) * 1e6 / 3
        if csv_rows is not None:
            csv_rows.append((f"fed_lm_round/{name}", us,
                             f"vectors={algo.vectors_up}up+{algo.vectors_down}dn"))


def bytes_to_target(csv_rows=None, target: float = 1e-6):
    """Transmitted bytes needed to reach a target error (lower = better)."""
    problem = make_quadratic_problem(0)
    from repro.core.simulate import paper_fig1_algorithms

    algos = paper_fig1_algorithms(problem, tau=2)
    for name, algo in algos.items():
        res = simulate_quadratic(algo, problem, rounds=3000)
        errs = res.errors
        k = next((i for i, e in enumerate(errs) if float(e) < target), None)
        note = (f"bytes={k * res.bytes_per_round}" if k is not None
                else "target_not_reached")
        if csv_rows is not None:
            csv_rows.append((f"bytes_to_{target:g}/{name}", 0.0, note))


def run(csv_rows=None):
    lm_round_times(csv_rows)
    bytes_to_target(csv_rows)


if __name__ == "__main__":
    rows = []
    run(csv_rows=rows)
    for r in rows:
        print(",".join(map(str, r)))
