"""Benchmark: kernel-bound federated LM rounds — the packed parameter
arena + fused round tail vs the per-leaf lowering, plus the legacy
round-throughput table and the error-vs-bytes trade-off.

The arena lowering (``with_arena``, repro/core/arena.py) keeps the whole
client store as one contiguous ``[clients, rows, 1024]`` f32 buffer; the
fused round tail (``FedCET._fused_tail`` -> kernels/ops.py
``fedcet_round_tail``) collapses the shift:q8 dequantize + weighted
client mean + paired FedCET ``(d', x')`` update + DIANA shift step into
one visit per element, with the int8 quantizer codes as the only
intermediate that touches memory.

The interesting regime is LARGE cohorts: at ``CLIENTS = 128`` on the
reduced fedlm-100m geometry the round tail's working set (~0.8 GB per
model-sized buffer) streams from DRAM. The measured finding on the dev
host (single CPU core, ~4 GB/s stream) is deliberately two-sided:

* the fused arena tail runs AT the roofline — its achieved bandwidth
  (model-implied 39 B/elem over measured time) lands on the host's
  stream rate, i.e. the two-pass is bytes-optimal;
* XLA's per-leaf whole-tail fusion ALSO reaches that floor (~40 B/elem
  model), so the wall-clock tail ratio on CPU is a WASH (~0.95-1.05x),
  and the full arena round pays its pack crossings without a
  compensating tail win (~1.1-1.3x the per-leaf round). The fused
  lowering's claim on this host is therefore structural, not
  wall-clock: the seam collapses from hundreds of compiled HLO
  instructions (dozens per leaf) to a handful (one kernel visit per
  element), which is what the TPU Mosaic path monetizes as dispatch
  and VMEM-residency wins.

The committed findings live in results/BENCH_fed_lm.json; full (non
``--quick``) runs RE-ASSERT conservative pins under the measured
values:

1. tail/fused >= TAIL_WASH_MIN x tail/per_leaf at CLIENTS=128 (the
   arena lowering never falls off the per-leaf floor — regression
   guard for the wash finding);
2. the compiled fused tail uses >= HLO_MIN_COLLAPSE x fewer HLO
   instructions than the compiled per-leaf tail (the structural
   one-visit-per-element claim, asserted on the optimized modules);
3. round/arena_kernel <= ROUND_MAX_OVERHEAD x round/per_leaf (the
   arena round's crossing overhead stays bounded);
4. a roofline check: the fused tail's achieved DRAM bandwidth
   (model-implied bytes / measured time) lands within loose bounds of
   the host's ~2 GB/s stream anchor — i.e. the tail is memory
   streaming, not compute- or overhead-bound.

``--quick`` (CI) drops to CLIENTS=8 and skips the assertions — the
cache-resident regime does not exhibit the pinned behavior.
"""

from __future__ import annotations

try:
    from benchmarks._timing import min_of_batches, results_dir, \
        write_bench_json
except ImportError:  # run directly as a script: benchmarks/ is sys.path[0]
    from _timing import min_of_batches, results_dir, write_bench_json

ARCH = "fedlm-100m"
CLIENTS = 128        # DRAM-streaming regime (quick: 8, cache-resident)
TAU = 1
BATCH = 1
SEQ = 16             # tiny gradients: the round tail dominates
ROUNDS = 1           # rounds per timed call
REPS = 1
BATCHES = 2
LEGACY_CLIENTS, LEGACY_TAU, LEGACY_BATCH, LEGACY_SEQ = 4, 2, 4, 64

# conservative pins under the measured findings (full mode only; dev
# host measured tail ratio ~0.95-1.05 (wash at the stream floor), round
# ~1.1-1.3x arena overhead, and a ~10x+ compiled-op collapse at
# CLIENTS=128, with +-15% run-to-run noise on the shared box).
TAIL_WASH_MIN = 0.70
HLO_MIN_COLLAPSE = 3.0
ROUND_MAX_OVERHEAD = 1.8
# host stream rate the roofline check is anchored to (measured ~2 GB/s
# single-core triad on the dev host), with loose machine-drift bounds.
STREAM_GBPS = 2.0
STREAM_BOUNDS = (0.25, 4.0)
# model-implied DRAM bytes per element of the fused two-pass tail:
#   pass 1 (codes):  read v + h (4+4), write int8 q   (1)       =  9
#   mean:            read q + h (1+4)                           =  5
#   pass 2 (sweep):  read q + h + d + v (1+4+4+4),
#                    write d' + x' + h' (4+4+4)                 = 25
TAIL_BYTES_PER_ELEM = 39


def _setup(n_clients: int, tau: int, batch: int, seq: int):
    import jax

    from repro.configs import get_config
    from repro.data.synthetic import make_hetero_lm_dataset
    from repro.models import build_model

    cfg = get_config(ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    ds = make_hetero_lm_dataset(cfg.vocab_size, n_clients, seq, batch, seed=0)
    batches = {"tokens": ds.sample_round(0, tau)}
    return model, params, batches


def _time_round(algo, model, params, batches) -> float:
    """Best-of-batches per-round microseconds via the donated repeat-mode
    runner (in-place client-store updates; the holder rebinds the carry)."""
    import jax

    from repro.core import make_round_runner

    grad_fn = jax.grad(model.loss)
    init_b = jax.tree.map(lambda b: b[0], batches)
    state = algo.init(grad_fn, params, init_b)
    runner = make_round_runner(algo, grad_fn, repeat=True, donate=True)
    holder = {"s": state}  # donated carry: rebind every call

    def once():
        s, _ = runner(holder["s"], batches, ROUNDS)
        holder["s"] = s
        return s

    best_us, _ = min_of_batches(once, reps=REPS, batches=BATCHES)
    return best_us / ROUNDS


def _round_variants(n_clients: int) -> dict:
    from repro.core import FedCET, with_arena, with_compression

    def fedcet(fused: bool):
        return FedCET(alpha=3e-3, c=0.05, tau=TAU, n_clients=n_clients,
                      use_fused_kernel=fused)

    comp = lambda a: with_compression(a, compressor="shift:q8")  # noqa: E731
    return {
        "per_leaf": comp(fedcet(False)),
        "arena": with_arena(comp(fedcet(False))),
        "arena_kernel": with_arena(comp(fedcet(True))),
    }


def arena_round_times(csv_rows=None, quick: bool = False) -> dict:
    """round/{per_leaf, arena, arena_kernel}: full FedCET x shift:q8 round
    wall time on the reduced fedlm-100m at the benchmark cohort size."""
    n = 8 if quick else CLIENTS
    model, params, batches = _setup(n, TAU, BATCH, SEQ)
    times = {}
    for name, algo in _round_variants(n).items():
        t = _time_round(algo, model, params, batches)
        times[f"round/{name}"] = t
        if csv_rows is not None:
            csv_rows.append((f"fed_lm/round/{name}", t,
                             f"clients={n};tau={TAU};B={BATCH};S={SEQ}"))
    return times


def tail_times(csv_rows=None, quick: bool = False):
    """tail/{per_leaf, fused}: the isolated round tail — dithered shift:q8
    quantize + reconstruct + client mean + paired FedCET ``(d', x')`` +
    DIANA h-step — in the two lowerings, plus the optimized-HLO
    instruction counts of both compiled tails. ``per_leaf`` is the TRUE
    per-leaf seam: the same math as a per-leaf ``jax.tree.map`` over the
    model's stacked leaves, unbarriered, exactly as XLA sees it on the
    per-leaf engine path. ``fused`` is the arena lowering through
    kernels/ops.py ``fedcet_round_tail`` (``'auto'``: the barriered
    two-pass whose second sweep re-reads 1-byte codes). per_leaf is
    timed FIRST — within-process drift then inflates the fused row,
    keeping the pinned wash floor conservative."""
    import jax
    import jax.numpy as jnp

    from repro.core import replicate
    from repro.core.arena import ArenaLayout, pack
    from repro.kernels import ops as kops

    n = 8 if quick else CLIENTS
    model, params, _ = _setup(n, TAU, BATCH, SEQ)
    lo = ArenaLayout.for_tree(params)
    rows = lo.rows
    c, alpha, beta = 0.05, 3e-3, 0.5

    # per-leaf operands: stacked [clients, ...] trees + model-shaped dither
    # + per-leaf scalar scales (precomputed, as the fused row's scale is).
    vt = replicate(params, n)
    ht = jax.tree.map(lambda a: 0.5 * a, vt)
    dt = jax.tree.map(jnp.zeros_like, vt)
    ks = jax.random.split(jax.random.key(1), len(lo.shapes))
    ut = jax.tree.unflatten(lo.treedef,
                            [jax.random.uniform(k, s, lo.dtype)
                             for k, s in zip(ks, lo.shapes)])
    st = jax.tree.map(
        lambda vl, hl: jnp.max(jnp.abs(vl - hl)) / 127.0, vt, ht)

    @jax.jit
    def per_leaf_tail(vt, ht, dt, ut, st):
        def leaf(vl, hl, ul, dl, sl):
            inv = jnp.where(sl > 0, 1.0 / sl, 0.0)
            qs = jnp.clip(jnp.floor((vl - hl) * inv + ul), -127, 127) * sl
            m_bar = jnp.mean(hl + qs, axis=0, keepdims=True)
            delta = (hl + qs) - m_bar
            return dl + c * delta, vl - (c * alpha) * delta, hl + beta * qs

        return jax.tree.map(leaf, vt, ht, ut, dt, st)

    times = {}

    def once_per_leaf():
        return per_leaf_tail(vt, ht, dt, ut, st)

    best_us, _ = min_of_batches(once_per_leaf, reps=REPS, batches=BATCHES + 1)
    times["tail/per_leaf"] = best_us
    if csv_rows is not None:
        csv_rows.append(("fed_lm/tail/per_leaf", best_us,
                         f"clients={n};leaves={len(lo.shapes)}"))

    # fused operands: the SAME values in arena layout.
    v = pack(vt, lo).data
    h = 0.5 * v
    d = jnp.zeros_like(v)
    u = pack(ut, lo).data
    seg = jnp.asarray(lo.row_segments())
    scale = jnp.stack(jax.tree.leaves(st))[seg][:, None]
    w = jnp.ones((n, 1), v.dtype)
    den = jnp.full((1, 1), n, v.dtype)

    def once_fused():
        return kops.fedcet_round_tail(v, h, d, u, scale, w, den,
                                      c=c, alpha=alpha, beta=beta,
                                      bits=8, impl="auto")

    best_us, _ = min_of_batches(once_fused, reps=REPS, batches=BATCHES + 1)
    times["tail/fused"] = best_us
    if csv_rows is not None:
        csv_rows.append(("fed_lm/tail/fused", best_us,
                         f"clients={n};rows={rows}"))

    # the structural claim, machine-invariant: instruction counts of the
    # two OPTIMIZED compiled modules (one visit per element vs dozens of
    # fusions per leaf).
    def _op_count(lowered) -> int:
        txt = lowered.compile().as_text()
        return sum(1 for ln in txt.splitlines()
                   if " = " in ln and not ln.lstrip().startswith("//"))

    hlo_ops = {
        "per_leaf": _op_count(per_leaf_tail.lower(vt, ht, dt, ut, st)),
        "fused": _op_count(kops.fedcet_round_tail.lower(
            v, h, d, u, scale, w, den, c=c, alpha=alpha, beta=beta,
            bits=8, impl="auto")),
    }
    hlo_ops["collapse"] = hlo_ops["per_leaf"] / hlo_ops["fused"]
    if csv_rows is not None:
        csv_rows.append(("fed_lm/tail/hlo_collapse", hlo_ops["collapse"],
                         f"per_leaf_ops={hlo_ops['per_leaf']};"
                         f"fused_ops={hlo_ops['fused']}"))

    # roofline: achieved DRAM bandwidth from the model-implied byte count.
    elems = n * rows * 1024
    model_bytes = elems * TAIL_BYTES_PER_ELEM
    fused_s = times["tail/fused"] * 1e-6
    roofline = {
        "elements": int(elems),
        "model_bytes_fused": int(model_bytes),
        "achieved_gbps_fused": model_bytes / fused_s / 1e9,
        # same (minimal) byte count over the unfused time: how far the
        # re-streamed f32 traffic drags the effective rate down.
        "effective_gbps_per_leaf": model_bytes
        / (times["tail/per_leaf"] * 1e-6) / 1e9,
        "stream_gbps_anchor": STREAM_GBPS,
    }
    if csv_rows is not None:
        csv_rows.append(("fed_lm/tail/roofline",
                         roofline["achieved_gbps_fused"],
                         f"model_GB={model_bytes / 1e9:.2f};"
                         f"anchor_gbps={STREAM_GBPS}"))
    return times, roofline, hlo_ops


def lm_round_times(csv_rows=None) -> dict:
    """Legacy trajectory rows: per-round wall time for the four algorithm
    families at the original small geometry (C=4, tau=2, B=4, S=64)."""
    from repro.core import FedAvg, FedCET, FedTrack, Scaffold

    n, tau = LEGACY_CLIENTS, LEGACY_TAU
    model, params, batches = _setup(n, tau, LEGACY_BATCH, LEGACY_SEQ)
    algos = {
        "fedcet": FedCET(alpha=3e-3, c=0.05, tau=tau, n_clients=n),
        "fedavg": FedAvg(alpha=3e-3, tau=tau, n_clients=n),
        "scaffold": Scaffold(alpha_l=3e-3, tau=tau, n_clients=n),
        "fedtrack": FedTrack(alpha=3e-3, tau=tau, n_clients=n),
    }
    times = {}
    for name, algo in algos.items():
        t = _time_round(algo, model, params, batches)
        times[f"algo/{name}"] = t
        if csv_rows is not None:
            csv_rows.append((f"fed_lm_round/{name}", t,
                             f"vectors={algo.vectors_up}up+{algo.vectors_down}dn"))
    return times


def bytes_to_target(csv_rows=None, target: float = 1e-6) -> dict:
    """Transmitted bytes needed to reach a target error (lower = better).
    ``errors[0]`` is the pre-communication initial error; the target being
    met first at ``errors[k + 1]`` means k+1 communication rounds were
    paid, i.e. ``(k + 1) * bytes_per_round``. Rows that never reach the
    target carry ``inf`` in the value column."""
    from repro.core.simulate import paper_fig1_algorithms, simulate_quadratic
    from repro.data.quadratic import make_quadratic_problem

    problem = make_quadratic_problem(0)
    algos = paper_fig1_algorithms(problem, tau=2)
    out = {}
    for name, algo in algos.items():
        res = simulate_quadratic(algo, problem, rounds=3000)
        k = next((i for i, e in enumerate(res.errors[1:])
                  if float(e) < target), None)
        if k is None:
            nbytes, note = float("inf"), "target_not_reached"
        else:
            nbytes = float((k + 1) * res.bytes_per_round)
            note = f"rounds={k + 1};bytes_per_round={res.bytes_per_round}"
        out[name] = nbytes
        if csv_rows is not None:
            csv_rows.append((f"bytes_to_{target:g}/{name}", nbytes, note))
    return out


def run(csv_rows=None, quick: bool = False):
    times = {}
    times.update(arena_round_times(csv_rows, quick))
    tails, roofline, hlo_ops = tail_times(csv_rows, quick)
    times.update(tails)
    times.update(lm_round_times(csv_rows))
    targets = bytes_to_target(csv_rows)

    tail_ratio = times["tail/per_leaf"] / times["tail/fused"]
    round_overhead = times["round/arena_kernel"] / times["round/per_leaf"]
    write_bench_json(
        "fed_lm",
        config={"arch": ARCH, "clients": (8 if quick else CLIENTS),
                "tau": TAU, "batch": BATCH, "seq": SEQ,
                "rounds_per_call": ROUNDS, "reps": REPS, "batches": BATCHES,
                "legacy": {"clients": LEGACY_CLIENTS, "tau": LEGACY_TAU,
                           "batch": LEGACY_BATCH, "seq": LEGACY_SEQ},
                "quick": quick},
        timings=times,
        extra={"speedup": {"tail": tail_ratio,
                           "round_overhead": round_overhead},
               "roofline": roofline,
               "hlo_instructions": hlo_ops,
               "bytes_to_target_1e-6": targets},
        out_dir=results_dir())

    # ---- pinned measured findings (full sweep only; see module docstring)
    if not quick:
        assert tail_ratio >= TAIL_WASH_MIN, (
            "fused arena tail fell off the per-leaf stream floor",
            tail_ratio, TAIL_WASH_MIN)
        assert hlo_ops["collapse"] >= HLO_MIN_COLLAPSE, (
            "fused tail no longer collapses the compiled seam",
            hlo_ops, HLO_MIN_COLLAPSE)
        assert round_overhead <= ROUND_MAX_OVERHEAD, (
            "arena round crossing overhead out of bounds",
            round_overhead, ROUND_MAX_OVERHEAD)
        lo, hi = STREAM_BOUNDS
        rel = roofline["achieved_gbps_fused"] / STREAM_GBPS
        assert lo <= rel <= hi, (
            "fused tail bandwidth out of the memory-streaming regime",
            roofline["achieved_gbps_fused"], STREAM_GBPS)
    return times


if __name__ == "__main__":
    import sys

    rows = []
    run(csv_rows=rows, quick="--quick" in sys.argv)
    print("name,us_per_call,derived")
    for r in rows:
        print(",".join(map(str, r)))
